"""Mamba2 (SSD) blocks — the zamba2-7b substrate.

Chunked state-space-duality formulation (Dao & Gu 2024, "ssd_minimal"):
within a chunk the recurrence is computed as masked matmuls (MXU-friendly on
the TPU target), across chunks a short ``lax.scan`` carries the state. The
chunk computation is wrapped in ``jax.checkpoint`` so training stores only
chunk-boundary states.

Decode is the O(1) recurrent update — this is why zamba2/xlstm handle the
long_500k shape with constant physical state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard_act
from .common import ParamDef, rms_norm, swish


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba2_param_defs(cfg: Mamba2Config, prefix: str = "") -> Dict[str, ParamDef]:
    p = prefix
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.n_groups * cfg.d_state + cfg.n_heads
    return {
        f"{p}w_in": ParamDef((cfg.d_model, d_in_proj), ("embed", "conv_dim")),
        f"{p}conv_w": ParamDef((cfg.d_conv, cfg.conv_dim), (None, "conv_dim"), scale=0.5),
        f"{p}conv_b": ParamDef((cfg.conv_dim,), ("conv_dim",), init="zeros"),
        f"{p}a_log": ParamDef((cfg.n_heads,), ("ssm_heads",), init="zeros"),
        f"{p}dt_bias": ParamDef((cfg.n_heads,), ("ssm_heads",), init="zeros"),
        f"{p}d_skip": ParamDef((cfg.n_heads,), ("ssm_heads",), init="ones"),
        f"{p}norm_w": ParamDef((cfg.d_inner,), ("conv_dim",), init="ones"),
        f"{p}w_out": ParamDef((cfg.d_inner, cfg.d_model), ("conv_dim", "embed")),
    }


def _split_in_proj(zxbcdt: jnp.ndarray, cfg: Mamba2Config):
    d_in, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : d_in + cfg.conv_dim]
    dt = zxbcdt[..., d_in + cfg.conv_dim :]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, d_conv: int):
    """Depthwise causal conv over (b, s, c)."""
    pad = jnp.pad(xbc, ((0, 0), (d_conv - 1, 0), (0, 0)))
    # stack shifted views: (d_conv, b, s, c)
    views = jnp.stack([pad[:, i : i + xbc.shape[1], :] for i in range(d_conv)])
    out = jnp.einsum("kbsc,kc->bsc", views, w) + b
    return swish(out)


def mamba2_forward(
    x: jnp.ndarray,  # (b, s, d)
    params: Dict[str, jnp.ndarray],
    cfg: Mamba2Config,
    prefix: str = "",
    return_state: bool = False,
):
    """Full-sequence chunked SSD forward.

    With return_state=True also returns the decode-ready state dict
    (prefill path): padded chunk-tail steps have dt=0 ⇒ decay 1, zero input,
    so the carried state is exact."""
    p = prefix
    b, s, _ = x.shape
    h, pdim, n, g, q = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups, cfg.chunk

    zxbcdt = x @ params[f"{p}w_in"]
    z, xbc, dt = _split_in_proj(zxbcdt, cfg)
    xbc = _causal_conv(xbc, params[f"{p}conv_w"], params[f"{p}conv_b"], cfg.d_conv)
    xs = xbc[..., : cfg.d_inner].reshape(b, s, h, pdim)
    bmat = xbc[..., cfg.d_inner : cfg.d_inner + g * n].reshape(b, s, g, n)
    cmat = xbc[..., cfg.d_inner + g * n :].reshape(b, s, g, n)
    xs = shard_act(xs, ("batch", None, "ssm_heads", None))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params[f"{p}dt_bias"])  # (b,s,h)
    a = -jnp.exp(params[f"{p}a_log"].astype(jnp.float32))  # (h,)
    da = dt * a  # (b,s,h) log-decay per step

    # chunk the sequence (pad to multiple of q)
    pad = (-s) % q
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // q
    xs_c = xs.reshape(b, nc, q, h, pdim)
    b_c = bmat.reshape(b, nc, q, g, n)
    c_c = cmat.reshape(b, nc, q, g, n)
    da_c = da.reshape(b, nc, q, h)
    dt_c = dt.reshape(b, nc, q, h)

    da_cs = jnp.cumsum(da_c, axis=2)  # (b,nc,q,h) inclusive cumsum

    @jax.checkpoint
    def chunk_body(state, inp):
        """state: (b, h, p, n); one chunk's SSD computation."""
        xs_i, b_i, c_i, da_cs_i, dt_i = inp  # (b,q,h,p),(b,q,g,n),(b,q,g,n),(b,q,h),(b,q,h)
        # broadcast groups → heads
        rep = h // g
        b_h = jnp.repeat(b_i, rep, axis=2)  # (b,q,h,n)
        c_h = jnp.repeat(c_i, rep, axis=2)

        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(da_cs_i)  # (b,q,h) decay from chunk start to t
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", c_h, state) * decay_in[..., None]

        # intra-chunk: masked "attention" form
        seg = da_cs_i[:, :, None, :] - da_cs_i[:, None, :, :]  # (b,q,q,h) cs_i - cs_j
        iq = jnp.arange(q)
        causal = (iq[:, None] >= iq[None, :])[None, :, :, None]
        l_mat = jnp.where(causal, jnp.exp(seg), 0.0)  # (b,q,q,h)
        scores = jnp.einsum("bqhn,bkhn->bqkh", c_h, b_h) * l_mat * dt_i[:, None, :, :]
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", scores, xs_i.astype(jnp.float32))

        # state for next chunk
        decay_out = jnp.exp(da_cs_i[:, -1:, :] - da_cs_i)  # decay from t to chunk end
        weighted_x = xs_i.astype(jnp.float32) * (dt_i * decay_out)[..., None]
        new_state = jnp.exp(da_cs_i[:, -1, :])[..., None, None] * state + jnp.einsum(
            "bqhp,bqhn->bhpn", weighted_x, b_h
        )
        return new_state, (y_inter + y_intra)

    state0 = jnp.zeros((b, h, pdim, n), jnp.float32)
    xs_t = xs_c.transpose(1, 0, 2, 3, 4)
    b_t = b_c.transpose(1, 0, 2, 3, 4)
    c_t = c_c.transpose(1, 0, 2, 3, 4)
    da_t = da_cs.transpose(1, 0, 2, 3)
    dt_t = dt_c.transpose(1, 0, 2, 3)
    final_state, y_chunks = jax.lax.scan(
        chunk_body, state0, (xs_t, b_t, c_t, da_t, dt_t)
    )
    y = y_chunks.transpose(1, 0, 2, 3, 4).reshape(b, nc * q, h, pdim)[:, :s]

    y = y + xs[:, :s].astype(jnp.float32) * params[f"{p}d_skip"][None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * swish(z), params[f"{p}norm_w"])
    out = y @ params[f"{p}w_out"]
    if not return_state:
        return out
    # conv state: last (d_conv-1) RAW xbc inputs (pre-conv, pre-activation)
    zxbcdt_raw = x @ params[f"{p}w_in"]
    _, xbc_raw, _ = _split_in_proj(zxbcdt_raw, cfg)
    conv_state = xbc_raw[:, s - (cfg.d_conv - 1):, :] if s >= cfg.d_conv - 1 else jnp.pad(
        xbc_raw, ((0, 0), (cfg.d_conv - 1 - s, 0), (0, 0))
    )
    return out, {"conv": conv_state.astype(x.dtype), "ssm": final_state}


# ---------------------------------------------------------------------------
# decode (recurrent, O(1) state)
# ---------------------------------------------------------------------------


def mamba2_state_init(cfg: Mamba2Config, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
    }


def mamba2_decode_step(
    x: jnp.ndarray,  # (b, 1, d)
    state: Dict[str, jnp.ndarray],
    params: Dict[str, jnp.ndarray],
    cfg: Mamba2Config,
    prefix: str = "",
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    p = prefix
    b = x.shape[0]
    h, pdim, n, g = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups

    zxbcdt = (x[:, 0] @ params[f"{p}w_in"])  # (b, ...)
    z, xbc, dt = _split_in_proj(zxbcdt, cfg)

    conv_win = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # (b,dc,c)
    xbc = swish(
        jnp.einsum("bkc,kc->bc", conv_win, params[f"{p}conv_w"]) + params[f"{p}conv_b"]
    )
    new_conv = conv_win[:, 1:]

    xs = xbc[..., : cfg.d_inner].reshape(b, h, pdim)
    bmat = xbc[..., cfg.d_inner : cfg.d_inner + g * n].reshape(b, g, n)
    cmat = xbc[..., cfg.d_inner + g * n :].reshape(b, g, n)
    rep = h // g
    b_h = jnp.repeat(bmat, rep, axis=1)  # (b,h,n)
    c_h = jnp.repeat(cmat, rep, axis=1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params[f"{p}dt_bias"])  # (b,h)
    a = -jnp.exp(params[f"{p}a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)  # (b,h)

    ssm = state["ssm"] * da[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xs.astype(jnp.float32) * dt[..., None], b_h.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", ssm, c_h.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * params[f"{p}d_skip"][None, :, None]
    y = y.reshape(b, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * swish(z), params[f"{p}norm_w"])
    out = (y @ params[f"{p}w_out"])[:, None, :]
    return out, {"conv": new_conv, "ssm": ssm}
