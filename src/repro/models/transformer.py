"""Decoder block + scan-over-layers stack for dense / moe / vlm families.

The stack is a single ``jax.lax.scan`` over stacked per-layer params (HLO size
independent of depth — required for 80-layer dry-runs), with optional
``jax.checkpoint`` per layer for training.

Neuron-chunking integration (first-class, paper §3): every block accepts an
optional ``sparse_ctx`` (serving/sparse_exec.SparseExecution). When present,
the block computes input importances for the q/o/gate/down projections
(k/v/up share masks per paper App. A), runs utility-guided chunk selection
*inside the jit*, applies the masks, and accumulates the additive-model I/O
latency estimate. Dense training never pays for this path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.quantize import (
    DECODE_COPY_SUFFIX,
    QUANT_SUFFIX_CHECKSUM,
    QUANT_SUFFIX_PAYLOAD,
    QUANT_SUFFIX_SCALE,
)
from ..sharding import shard_act
from .attention import (
    attention_param_defs,
    cache_layer_update,
    decode_attention,
    gather_paged_kv,
    multi_head_attention,
    project_kv_for_decode,
    scatter_paged_kv,
)
from .common import ParamDef, layer_norm, rms_norm
from .mlp import (
    gelu_mlp,
    gelu_mlp_param_defs,
    gelu_mlp_planned,
    mlp_param_defs,
    swiglu_mlp,
    swiglu_mlp_planned,
)
from .moe import MoEConfig, moe_ffn, moe_param_defs


def _norm_defs(cfg: ModelConfig, name: str) -> Dict[str, ParamDef]:
    defs = {f"{name}_w": ParamDef((cfg.d_model,), ("embed",), init="ones")}
    if cfg.norm == "layernorm":
        defs[f"{name}_b"] = ParamDef((cfg.d_model,), ("embed",), init="zeros")
    return defs


def apply_norm(x, params, cfg: ModelConfig, name: str):
    if cfg.norm == "layernorm":
        return layer_norm(x, params[f"{name}_w"], params[f"{name}_b"])
    return rms_norm(x, params[f"{name}_w"])


def moe_cfg_of(cfg: ModelConfig) -> MoEConfig:
    return MoEConfig(
        n_experts=cfg.n_experts,
        top_k=cfg.moe_top_k,
        d_model=cfg.d_model,
        d_ff=cfg.d_ff,
        capacity_factor=cfg.moe_capacity_factor,
        shared_expert=cfg.moe_shared_expert,
        dispatch=cfg.moe_dispatch,
    )


def block_param_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    """One decoder block's params (unstacked)."""
    defs: Dict[str, ParamDef] = {}
    defs.update(_norm_defs(cfg, "ln1"))
    defs.update(_norm_defs(cfg, "ln2"))
    defs.update(
        attention_param_defs(
            cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        )
    )
    if cfg.has_moe:
        defs.update(moe_param_defs(moe_cfg_of(cfg)))
    elif cfg.mlp == "gelu":
        defs.update(gelu_mlp_param_defs(cfg.d_model, cfg.d_ff))
    else:
        defs.update(mlp_param_defs(cfg.d_model, cfg.d_ff))
    return defs


def _apply_mask(x, mask):
    return x if mask is None else x * mask.astype(x.dtype)


# the offloaded per-layer matrices governed by sparsification — the set the
# engine quantizes (kernels/quantize.py) when serving at wbits=8; names
# absent from an arch family (gelu vs swiglu MLPs) are skipped
SPARSE_WEIGHT_NAMES = (
    "wq", "wk", "wv", "wo",
    "w_gate", "w_up", "w_down",  # swiglu family
    "w_fc", "w_proj",  # non-gated gelu family
)


def site_matrix_names(cfg: ModelConfig) -> Dict[str, Tuple[str, ...]]:
    """Which stored matrices stream through each sparsification site, in
    site matrix order — the integrity subsystem's twin of
    ``core.offload.decode_site_shapes`` (must agree with
    ``SparseExecution.site_matrix_count``)."""
    names: Dict[str, Tuple[str, ...]] = {
        "hidden_attn": ("wq", "wk", "wv"),
        "attn_out": ("wo",),
    }
    if cfg.d_ff and not cfg.has_moe:
        if cfg.mlp == "gelu":
            names["hidden_mlp"] = ("w_fc",)
            names["ffn"] = ("w_proj",)
        else:
            names["hidden_mlp"] = ("w_gate", "w_up")
            names["ffn"] = ("w_down",)
    return names


def _integrity_weights(params, sparse_ctx, cfg: ModelConfig, plan):
    """The per-site ((payload, checksums), ...) matrices ``refresh_layer``
    verifies fetched blocks against (corruption injection only): the same
    stored payload leaf the execution backend streams, paired with its
    pack-time ``_ck`` lane. None when integrity is off — the refresh is
    then bit-identical to a build without the subsystem."""
    if not getattr(sparse_ctx, "integrity_enabled", False):
        return None
    names = site_matrix_names(cfg)
    return {
        kind: tuple(
            (_site_weight(params, sparse_ctx, nm)[0],
             params[nm + QUANT_SUFFIX_CHECKSUM])
            for nm in names[kind]
        )
        for kind in plan
    }


def _site_weight(params, sparse_ctx, name):
    """One offloaded matrix in the form the planned decode path streams it:
    the (int8 payload, per-block scales) pair at wbits=8 — the quantized
    leaves the engine stores next to the fp originals — or (fp weight,
    None) at 16 bits. The execution backend dequantizes inside the gather
    (kernel) / before the identical contraction (reference twin)."""
    if (
        sparse_ctx is not None
        and getattr(sparse_ctx, "wbits", 16) == 8
        and name + QUANT_SUFFIX_PAYLOAD in params
    ):
        return params[name + QUANT_SUFFIX_PAYLOAD], params[name + QUANT_SUFFIX_SCALE]
    if sparse_ctx is not None and name + DECODE_COPY_SUFFIX in params:
        # sharded serving at wbits=16: stream the model-axis-sharded decode
        # copy; the replicated fp original stays for prefill/frame append
        return params[name + DECODE_COPY_SUFFIX], None
    return params[name], None


def block_forward(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # (b, s, d)
    cfg: ModelConfig,
    positions: Optional[jnp.ndarray],
    window: Optional[int],
    sparse_ctx: Any = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (x_out, moe_aux, io_latency_s)."""
    io = jnp.float32(0.0)
    h = apply_norm(x, params, cfg, "ln1")
    h = shard_act(h, ("batch", None, "act_embed"))

    mask_q = None
    if sparse_ctx is not None:
        mask_q, lat = sparse_ctx.mask("hidden_attn", h)
        io += lat
    attn_in = _apply_mask(h, mask_q)
    attn_raw = multi_head_attention(
        attn_in,
        params,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.resolved_head_dim,
        positions=positions,
        rope_theta=cfg.rope_theta,
        causal=True,
        window=window,
        project_out=sparse_ctx is None,
    )
    if sparse_ctx is not None:
        mask_o, lat = sparse_ctx.mask("attn_out", attn_raw)
        io += lat
        attn_raw = _apply_mask(attn_raw, mask_o) @ params["wo"]
    x = x + attn_raw

    h = apply_norm(x, params, cfg, "ln2")
    h = shard_act(h, ("batch", None, "act_embed"))
    aux = jnp.float32(0.0)
    if cfg.has_moe:
        y, aux = moe_ffn(h, params, moe_cfg_of(cfg))
    else:
        y, lat, _ = _mlp_maybe_sparse(h, params, cfg, sparse_ctx)
        io += lat
    x = x + y
    x = shard_act(x, ("batch", "act_seq", "act_embed"))
    return x, aux, io


def _planned_mlp(h, params, cfg: ModelConfig, sparse_ctx, plan):
    """Planned-decode sparse MLP: masks were refreshed at the top of the
    block (one batched dispatch), so here we only read them, run the MLP
    through the decode execution backend off the plan's kernel chunk-table
    lanes, and record this step's importances for the NEXT refresh. The
    backend (``reference`` schedule twin vs ``kernel`` DMA gather) only
    changes how the arithmetic is realized — outputs are bitwise identical.

    Returns (y, io_latency (always 0 — the refresh charged it), new_plan).
    """
    backend = sparse_ctx.backend
    mask_g = plan["hidden_mlp"]["mask"]
    mask_f = plan["ffn"]["mask"]
    plan = sparse_ctx.record_importance("hidden_mlp", h, plan)
    qname = "w_fc" if cfg.mlp == "gelu" else "w_gate"
    quantized = (
        getattr(sparse_ctx, "wbits", 16) == 8
        and qname + QUANT_SUFFIX_PAYLOAD in params
    )
    if getattr(sparse_ctx, "integrity_corrupting", False):
        # recovery-OFF corruption: damage the MLP payload leaves the
        # planned functions stream, in a shallow params copy (both
        # backends consume the identical damaged operands)
        names = site_matrix_names(cfg)
        params = dict(params)
        for kind in ("hidden_mlp", "ffn"):
            for mi, nm in enumerate(names[kind]):
                leaf = nm + QUANT_SUFFIX_PAYLOAD if quantized else nm
                params[leaf] = sparse_ctx.apply_corruption(
                    plan, kind, mi, params[leaf]
                )
    if cfg.mlp == "gelu":
        y, mid = gelu_mlp_planned(
            h, params, backend, mask_g, mask_f,
            sparse_ctx.kernel_tables(plan, "hidden_mlp"),
            sparse_ctx.kernel_tables(plan, "ffn"),
            quantized=quantized,
        )
    else:
        starts, sizes = sparse_ctx.mlp_kernel_plan(plan)
        y, mid = swiglu_mlp_planned(
            h, params, backend, mask_g, mask_f, starts, sizes,
            quantized=quantized,
        )
    plan = sparse_ctx.record_importance("ffn", mid, plan)
    return y, jnp.float32(0.0), plan


def _mlp_maybe_sparse(h, params, cfg: ModelConfig, sparse_ctx, plan=None):
    """Gated/plain MLP with the paper's gate(+up-shared) and down masks.

    Returns (y, io_latency, new_plan); plan is passed through untouched on
    the unplanned paths (forward / append / per-token decode). When a
    decode plan carries the MLP sites, the compute routes through
    ``_planned_mlp`` (the execution-backend path) instead of the masked
    dense matmuls below."""
    if sparse_ctx is None:
        y = gelu_mlp(h, params) if cfg.mlp == "gelu" else swiglu_mlp(h, params)
        return y, jnp.float32(0.0), plan
    if plan is not None and "hidden_mlp" in plan and "ffn" in plan:
        return _planned_mlp(h, params, cfg, sparse_ctx, plan)
    mask_g, io1, plan = _site_mask(sparse_ctx, "hidden_mlp", h, plan)
    hm = _apply_mask(h, mask_g)
    if cfg.mlp == "gelu":
        mid = jax.nn.gelu(hm @ params["w_fc"] + params["b_fc"])
        mask_f, io2, plan = _site_mask(sparse_ctx, "ffn", mid, plan)
        y = _apply_mask(mid, mask_f) @ params["w_proj"] + params["b_proj"]
    else:
        from .common import swish

        mid = swish(hm @ params["w_gate"]) * (hm @ params["w_up"])
        mask_f, io2, plan = _site_mask(sparse_ctx, "ffn", mid, plan)
        y = _apply_mask(mid, mask_f) @ params["w_down"]
    return y, io1 + io2, plan


def stack_forward(
    stacked: Dict[str, jnp.ndarray],  # each leaf (L, ...)
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: Optional[jnp.ndarray],
    window: Optional[int],
    remat: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scan the block over L layers. Returns (hidden, total_moe_aux)."""

    def body(carry, layer_params):
        h, aux = carry
        h2, aux2, _ = block_forward(layer_params, h, cfg, positions, window)
        return (h2, aux + aux2), None

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), stacked)
    return x, aux


# ---------------------------------------------------------------------------
# decode (one token, stacked KV cache)
# ---------------------------------------------------------------------------


def _site_mask(sparse_ctx, kind: str, acts, plan):
    """One sparsification site, optionally through a reusable chunk plan.

    Without a plan (``plan is None`` or the site has none) this is exactly
    ``sparse_ctx.mask`` — in-step per-site selection. With a plan, the
    layer's masks were already refreshed in ONE batched dispatch at the top
    of the block (``sparse_ctx.refresh_layer`` in ``block_decode``, which
    also charged the I/O); here we only read the current mask and record
    this step's importance as the input to the NEXT refresh (the
    prefetch-compatible deferred-selection contract, see docs/serving.md).

    Returns (mask, io_latency, new_plan).
    """
    if sparse_ctx is None:
        return None, jnp.float32(0.0), plan
    if plan is None or kind not in plan:
        m, lat = sparse_ctx.mask(kind, acts)
        return m, lat, plan
    new_plan = sparse_ctx.record_importance(kind, acts, plan)
    return new_plan[kind]["mask"], jnp.float32(0.0), new_plan


def block_decode(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # (b, 1, d)
    layer_k: jnp.ndarray,
    layer_v: jnp.ndarray,
    length: jnp.ndarray,  # tokens in cache BEFORE this one; () or (b,)
    cfg: ModelConfig,
    window: Optional[int],
    sparse_ctx: Any = None,
    plan: Optional[Dict[str, jnp.ndarray]] = None,  # per-layer site masks
    refresh: Optional[jnp.ndarray] = None,  # scalar bool: recompute selection
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, Any]:
    """Returns (x_out, new_k, new_v, io_latency, new_plan)."""
    io = jnp.float32(0.0)
    if sparse_ctx is not None and plan:
        # planned path: ONE batched selection dispatch refreshes every
        # site's mask for this layer (or reuses them at zero I/O); with
        # corruption injection on, the refresh also draws/verifies corrupt
        # blocks against the stored payloads' checksum lanes
        plan, sel_lat = sparse_ctx.refresh_layer(
            plan, refresh,
            weights=_integrity_weights(params, sparse_ctx, cfg, plan),
        )
        io += sel_lat
    h = apply_norm(x, params, cfg, "ln1")

    mask_q, lat, plan = _site_mask(sparse_ctx, "hidden_attn", h, plan)
    io += lat
    attn_in = _apply_mask(h, mask_q)
    q_pre = kv_pre = None
    if plan is not None and "hidden_attn" in plan:
        # planned path: the q/k/v projections run through the execution
        # backend off the hidden_attn chunk table — the same reference-twin /
        # chunk_gather_matmul_dma dispatch as every other site (closing the
        # last masked-dense residue of the decode hot path)
        b, s, _ = h.shape
        hs, hz = sparse_ctx.kernel_tables(plan, "hidden_attn")
        hflat = h.reshape(b * s, -1)
        outs = []
        for mi, name in enumerate(("wq", "wk", "wv")):
            w, sc = _site_weight(params, sparse_ctx, name)
            # recovery-OFF corruption: the damaged payload flows into the
            # gather on BOTH backends (no-op unless integrity_corrupting)
            w = sparse_ctx.apply_corruption(plan, "hidden_attn", mi, w)
            y = sparse_ctx.backend.project(
                w, hflat, mask_q, hs, hz, sc,
                params.get(name + QUANT_SUFFIX_CHECKSUM),
            )
            outs.append(y.astype(h.dtype).reshape(b, s, -1))
        q_pre, k_pre, v_pre = outs
        kv_pre = (k_pre, v_pre)
    new_k, new_v = project_kv_for_decode(
        attn_in, params, cfg.n_kv_heads, cfg.resolved_head_dim, length,
        cfg.rope_theta, kv=kv_pre,
    )
    if cfg.kv_replicate > 1:  # shardable-cache replication (§Perf iteration A)
        from .attention import repeat_kv

        new_k = repeat_kv(new_k, cfg.kv_replicate)
        new_v = repeat_kv(new_v, cfg.kv_replicate)
    layer_k, layer_v = cache_layer_update(
        layer_k, layer_v, new_k, new_v, length, window
    )
    attn_raw = decode_attention(
        attn_in,
        params,
        layer_k,
        layer_v,
        length + 1,
        cfg.n_heads,
        cfg.n_cache_kv_heads,
        cfg.resolved_head_dim,
        cfg.rope_theta,
        window,
        project_out=sparse_ctx is None,
        q=q_pre,
    )
    if sparse_ctx is not None:
        mask_o, lat, plan = _site_mask(sparse_ctx, "attn_out", attn_raw, plan)
        io += lat
        if plan is not None and "attn_out" in plan:
            # planned path: the single-site o-projection runs through the
            # execution backend off the plan's chunk table (reference twin
            # or chunk_gather_matmul_dma — bitwise identical)
            b, s, _ = attn_raw.shape
            w_o, sc_o = _site_weight(params, sparse_ctx, "wo")
            w_o = sparse_ctx.apply_corruption(plan, "attn_out", 0, w_o)
            y_o = sparse_ctx.backend.project(
                w_o, attn_raw.reshape(b * s, -1), mask_o,
                *sparse_ctx.kernel_tables(plan, "attn_out"), sc_o,
                params.get("wo" + QUANT_SUFFIX_CHECKSUM),
            )
            attn_raw = y_o.astype(attn_raw.dtype).reshape(b, s, -1)
        else:
            attn_raw = _apply_mask(attn_raw, mask_o) @ params["wo"]
    x = x + attn_raw

    h = apply_norm(x, params, cfg, "ln2")
    if cfg.has_moe:
        y, _ = moe_ffn(h, params, moe_cfg_of(cfg))
    else:
        y, lat, plan = _mlp_maybe_sparse(h, params, cfg, sparse_ctx, plan)
        io += lat
    x = x + y
    return x, layer_k, layer_v, io, plan


def stack_decode(
    stacked: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cache: Dict[str, jnp.ndarray],  # k/v: (L, b, P, kv, hd), length: () or (b,)
    cfg: ModelConfig,
    window: Optional[int],
    sparse_ctx: Any = None,
    plan: Optional[Dict[str, jnp.ndarray]] = None,  # {site: (L, N)} masks
    refresh: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray, Any]:
    """Scan the decode block over layers. ``plan`` (when not None) carries
    each layer's cached chunk masks as scan inputs and the refreshed masks
    come back as scan outputs — so a fused multi-token decode loop can reuse
    selection across steps. Returns (x, new_cache, io, new_plan) where
    ``io`` is the PER-LAYER I/O-estimate vector (n_layers,) — the input the
    engine's overlapped prefetch timeline (core/pipeline.py) needs; sum it
    for the legacy scalar total."""
    length = cache["length"]
    planned = plan is not None and len(plan) > 0
    paged = "page_table" in cache
    if paged:
        # paged layout: cache k/v are per-layer page POOLS
        # (L, n_pages, page_tokens, kv, hd) and the table (b, max_pages)
        # rides the scan carry as a traced int32 leaf. Each layer gathers a
        # dense view (bit-equal shape to the dense cache), runs the
        # unchanged block, and scatters the one new entry back to its page.
        assert window is None, "paged KV does not compose with sliding windows"
        table = cache["page_table"]

    def body(h, layer):
        if planned:
            layer_params, lk, lv, layer_plan = layer
        else:
            layer_params, lk, lv = layer
            layer_plan = None
        if paged:
            pool_k, pool_v = lk, lv
            lk = gather_paged_kv(pool_k, table)
            lv = gather_paged_kv(pool_v, table)
        h2, lk2, lv2, io2, plan2 = block_decode(
            layer_params, h, lk, lv, length, cfg, window, sparse_ctx,
            plan=layer_plan, refresh=refresh,
        )
        if paged:
            lk2 = scatter_paged_kv(pool_k, lk2, table, length)
            lv2 = scatter_paged_kv(pool_v, lv2, table, length)
        ys = (lk2, lv2, io2, plan2) if planned else (lk2, lv2, io2)
        return h2, ys

    xs = (
        (stacked, cache["k"], cache["v"], plan)
        if planned
        else (stacked, cache["k"], cache["v"])
    )
    x, ys = jax.lax.scan(body, x, xs)
    if planned:
        ks, vs, io, new_plan = ys
    else:
        (ks, vs, io), new_plan = ys, plan
    new_cache = {"k": ks, "v": vs, "length": length + 1}
    if paged:
        new_cache["page_table"] = table
    return x, new_cache, io, new_plan


# ---------------------------------------------------------------------------
# frame append (multi-token cache extension — the paper's VLM stage)
# ---------------------------------------------------------------------------


def block_append(
    params,
    x: jnp.ndarray,  # (b, n, d) new (visual) tokens
    layer_k,
    layer_v,
    length,
    cfg: ModelConfig,
    sparse_ctx: Any = None,
):
    from .attention import append_attention

    io = jnp.float32(0.0)
    h = apply_norm(x, params, cfg, "ln1")
    mask_q = None
    if sparse_ctx is not None:
        mask_q, lat = sparse_ctx.mask("hidden_attn", h)
        io += lat
    attn_in = _apply_mask(h, mask_q)
    attn_raw, layer_k, layer_v = append_attention(
        attn_in,
        params,
        layer_k,
        layer_v,
        length,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.resolved_head_dim,
        cfg.rope_theta,
        kv_replicate=cfg.kv_replicate,
        project_out=sparse_ctx is None,
    )
    if sparse_ctx is not None:
        mask_o, lat = sparse_ctx.mask("attn_out", attn_raw)
        io += lat
        attn_raw = _apply_mask(attn_raw, mask_o) @ params["wo"]
    x = x + attn_raw

    h = apply_norm(x, params, cfg, "ln2")
    if cfg.has_moe:
        y, _ = moe_ffn(h, params, moe_cfg_of(cfg))
    else:
        y, lat, _ = _mlp_maybe_sparse(h, params, cfg, sparse_ctx)
        io += lat
    return x + y, layer_k, layer_v, io


def stack_append(
    stacked,
    x: jnp.ndarray,
    cache: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    sparse_ctx: Any = None,
):
    """Append n tokens to every layer's cache (paper §2.1 frame appending)."""
    length = cache["length"]
    n = x.shape[1]

    def body(carry, layer):
        h, io = carry
        layer_params, lk, lv = layer
        h2, lk2, lv2, io2 = block_append(
            layer_params, h, lk, lv, length, cfg, sparse_ctx
        )
        return (h2, io + io2), (lk2, lv2)

    (x, io), (ks, vs) = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (stacked, cache["k"], cache["v"])
    )
    return x, {"k": ks, "v": vs, "length": length + n}, io


# ---------------------------------------------------------------------------
# prefill (full sequence, also fills the cache)
# ---------------------------------------------------------------------------


def block_prefill(
    params, x, cfg: ModelConfig, positions, window, phys_len: int
):
    """Like block_forward but also returns this layer's (k, v) cache fill."""
    from .attention import repeat_kv

    b, s, _ = x.shape
    h = apply_norm(x, params, cfg, "ln1")
    k = (h @ params["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.resolved_head_dim)
    v = (h @ params["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.resolved_head_dim)
    if cfg.rope_theta is not None:
        from .common import apply_rope

        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.kv_replicate > 1:
        k, v = repeat_kv(k, cfg.kv_replicate), repeat_kv(v, cfg.kv_replicate)
    # keep the LAST phys_len positions (rotating-window layout: slot = pos % P)
    if phys_len < s:
        keep_k, keep_v = k[:, -phys_len:], v[:, -phys_len:]
        roll = (s % phys_len)
        # place so that slot (pos % P) matches decode's rotating writes
        keep_k = jnp.roll(keep_k, shift=roll, axis=1)
        keep_v = jnp.roll(keep_v, shift=roll, axis=1)
    else:
        pad = phys_len - s
        keep_k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        keep_v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    x_out, aux, _ = block_forward(params, x, cfg, positions, window)
    return x_out, aux, keep_k, keep_v


def stack_prefill(
    stacked,
    x,
    cfg: ModelConfig,
    positions,
    window: Optional[int],
    phys_len: int,
    remat: bool = False,
):
    def body(carry, layer_params):
        h, aux = carry
        h2, aux2, k, v = block_prefill(layer_params, h, cfg, positions, window, phys_len)
        return (h2, aux + aux2), (k, v)

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), (ks, vs) = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), stacked)
    cache = {"k": ks, "v": vs, "length": jnp.int32(x.shape[1])}
    return x, aux, cache
