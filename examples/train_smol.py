"""Train a small LM end-to-end with the framework's full training substrate
(data pipeline → scan-over-layers model → chunked-CE train step → AdamW →
checkpoint). Default is CPU-sized (~10M params, 200 steps); --full uses a
~100M-param config (the assignment's train target — sized for accelerators).

  PYTHONPATH=src python examples/train_smol.py [--steps 200] [--full]
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, lm_batches
from repro.models import build_model
from repro.training import AdamWConfig, Trainer, save_checkpoint

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full", action="store_true", help="~100M params")
ap.add_argument("--ckpt", default=None)
args = ap.parse_args()

base = get_config("tinyllama-1.1b")
if args.full:
    # ~100M params: 12L × d768 × ff2048, 32k byte-level-padded vocab
    cfg = dataclasses.replace(
        base, name="smol-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
    )
    batch, seq = 16, 512
else:
    cfg = dataclasses.replace(
        base, name="smol-10m", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=512,
    )
    batch, seq = 16, 128

model = build_model(cfg)
n_params = sum(x.size for x in jax.tree.leaves(jax.eval_shape(model.init, jax.random.key(0))))
print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

trainer = Trainer(model, AdamWConfig(lr=6e-4, warmup_steps=20,
                                     total_steps=args.steps), loss_chunk=128)
params, opt = trainer.init_state(jax.random.key(0))
step = trainer.jit_train_step(donate=True)
it = lm_batches(cfg, DataConfig(batch=batch, seq_len=seq, seed=0))
t0 = time.time()
for i in range(args.steps):
    b = {k: jnp.asarray(v) for k, v in next(it).items()}
    params, opt, m = step(params, opt, b)
    if i % 20 == 0 or i == args.steps - 1:
        print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
              f"lr {float(m['lr']):.2e}  {(time.time()-t0)/(i+1):.2f}s/step",
              flush=True)
if args.ckpt:
    save_checkpoint(args.ckpt, params, step=args.steps)
    print("checkpoint saved:", args.ckpt)
