"""Accuracy–latency trade-off sweep (the paper's Fig. 6 protocol) on real
reduced-model activations: runs a reduced VLM, captures true layer inputs,
and sweeps sparsity × {top-k, threshold(CATS), neuron chunking}, reporting
importance retention, OUTPUT ERROR vs dense, and simulated I/O latency.

  PYTHONPATH=src python examples/compare_baselines.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import (
    ChunkConfig,
    ChunkSelector,
    calibrate_threshold,
    retention,
    threshold_mask,
    topk_mask_np,
)
from repro.models import build_model
from repro.models.inputs import make_dummy_batch

cfg = get_config("internvl2-76b").reduced()
model = build_model(cfg)
params = model.init(jax.random.key(0))
batch = make_dummy_batch(cfg, InputShape("s", 64, 2, "train"))

# capture a real mid-stack activation: embed + first block input
hidden, _ = model.forward(params, batch, remat=False)
acts = jnp.abs(hidden.astype(jnp.float32)).reshape(-1, cfg.d_model).mean(0)
v = np.asarray(acts)
n = cfg.d_model
w_down = np.asarray(params["layers"]["w_down"][0], np.float32).T  # (d, f)→use as (n,cols)
cols = w_down.shape[1]
sel = ChunkSelector.build(n, cols * 2, device="nano",
                          cfg=ChunkConfig(2, 348, 2, 2))
x_ref = np.asarray(hidden.astype(jnp.float32).reshape(-1, n))
y_dense = x_ref @ w_down

thr_cal = calibrate_threshold(v[None], 0.0)  # recalibrated per sparsity below

print(f"{'sparsity':>8s} {'method':>10s} {'retention':>10s} "
      f"{'out_rel_err':>12s} {'io_ms':>8s}")
for sp in (0.2, 0.4, 0.6):
    budget = int((1 - sp) * n)
    plans = {}
    plans["topk"] = jnp.asarray(topk_mask_np(v, budget))
    t = calibrate_threshold(v[None], sp)
    plans["cats"] = threshold_mask(jnp.asarray(v), t)
    m, _, _ = sel.select(jnp.asarray(v), jnp.int32(budget))
    plans["chunk"] = m
    for name, mask in plans.items():
        lat = float(sel.table.mask_latency(mask)) * 1e3
        ret = float(retention(jnp.asarray(v), mask))
        y = (x_ref * np.asarray(mask, np.float32)) @ w_down
        err = float(np.linalg.norm(y - y_dense) / np.linalg.norm(y_dense))
        print(f"{sp:8.1f} {name:>10s} {ret:10.3f} {err:12.3f} {lat:8.3f}")
