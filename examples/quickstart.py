"""Quickstart: NEURON CHUNKING on one offloaded weight matrix.

Shows the full per-matrix runtime path the paper executes ~200×/frame:
importance → utility-guided chunk selection → latency estimate → the Pallas
chunk-gather kernel computing y = Σ x_i W_i over only the selected chunks.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import (
    NeuronChunkingPlanner,
    chunk_stats_np,
    contiguity_distribution_np,
)
from repro.kernels import plan_to_kernel_table, sparse_matmul

N, D = 4096, 1024  # one down-projection-like matrix (rows = input neurons)
rng = np.random.default_rng(0)

# 1. a planner per offloaded matrix (device latency table baked in)
planner = NeuronChunkingPlanner.build(N, D, device="nano", dtype_bytes=2)

# 2. runtime: activations arrive → plan at 40% sparsity
acts = jnp.asarray(np.abs(rng.normal(0, 1, (16, N))) * rng.lognormal(0, 1, N))
plan = planner.plan(acts, sparsity=0.4)
topk = planner.plan_topk(acts, sparsity=0.4)

print(f"selected rows      : {int(plan.n_selected)} / {N}")
print(f"importance retained: ours {float(plan.importance_retention):.3f} "
      f"vs top-k {float(topk.importance_retention):.3f}")
print(f"est. I/O latency   : ours {float(plan.est_latency_s)*1e3:.3f} ms "
      f"vs top-k {float(topk.est_latency_s)*1e3:.3f} ms "
      f"({float(topk.est_latency_s)/float(plan.est_latency_s):.1f}x)")
mask = np.asarray(plan.mask)
print(f"contiguity         : avg chunk {chunk_stats_np(mask)[0]:.1f} rows "
      f"(top-k: {chunk_stats_np(np.asarray(topk.mask))[0]:.1f}); "
      f"distribution {dict(sorted(contiguity_distribution_np(mask).items())[:5])}...")

# 3. execute with the TPU kernel (interpret mode on CPU): only selected
#    chunks are ever fetched from HBM. The kernel table is the plan rounded
#    outward to the 8-row DMA grid (a slight superset — the TPU analogue of
#    the paper's KB-aligned chunks), so the oracle uses the same table.
from repro.kernels import chunk_gather_matmul_ref

w = jnp.asarray(rng.normal(0, 1, (N, D)), jnp.bfloat16)
starts, sizes = plan_to_kernel_table(mask, block_rows=8, max_chunk_rows=512)
x1 = acts[:1].astype(jnp.bfloat16)
y = sparse_matmul(w, x1, jnp.asarray(starts), jnp.asarray(sizes))
y_ref = chunk_gather_matmul_ref(w, x1, starts, sizes)
print(f"kernel vs oracle max err: {float(jnp.max(jnp.abs(y - y_ref))):.2e}")
