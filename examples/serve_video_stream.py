"""End-to-end driver (paper's workload): streaming-video VLM serving with
batched requests — prefill → per-frame appending → fused-scan decoding —
comparing dense loads, top-k sparsification, and NEURON CHUNKING on the
simulated Jetson Orin Nano flash device, plus the effect of temporal
chunk-plan reuse (recompute selection every k decode steps).

  PYTHONPATH=src python examples/serve_video_stream.py [--arch internvl2-76b]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.models import build_model
from repro.models.inputs import make_dummy_batch
from repro.serving import ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="internvl2-76b")
ap.add_argument("--frames", type=int, default=4)
ap.add_argument("--decode-tokens", type=int, default=12)
ap.add_argument("--sparsity", type=float, default=0.4)
ap.add_argument("--plan-refresh-interval", type=int, default=1)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
model = build_model(cfg)
params = model.init(jax.random.key(0))
prompt = make_dummy_batch(cfg, InputShape("s", 32, 2, "train"))
rng = np.random.default_rng(0)
frames = [
    jnp.asarray(rng.normal(0, 1, (2, 8, cfg.d_frontend)), jnp.bfloat16)
    for _ in range(args.frames)
]

print(f"{'policy':8s} {'frame io (ms)':>14s} {'decode io (ms/tok)':>20s} "
      f"{'total io (ms)':>14s}")
results = {}
for method in ("dense", "topk", "chunk"):
    eng = ServeEngine(model, params, max_seq=512, batch_size=2, device="nano",
                      sparsity=args.sparsity, method=method, seed=1,
                      plan_refresh_interval=args.plan_refresh_interval)
    last = eng.prefill(prompt)
    for f in frames:
        eng.append_frame(f)
    tok0 = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    eng.decode(tok0, args.decode_tokens)  # fused lax.scan decode loop
    fr = [s.io_sim_s for s in eng.stats if s.kind == "frame"]
    de = [s.io_sim_s for s in eng.stats if s.kind == "decode"]
    tot = sum(s.io_sim_s for s in eng.stats if s.kind != "prefill")
    results[method] = tot
    print(f"{method:8s} {np.mean(fr)*1e3:14.2f} {np.mean(de)*1e3:20.2f} "
          f"{tot*1e3:14.2f}")

print(f"\nneuron chunking vs top-k I/O speedup at EQUAL sparsity: "
      f"{results['topk']/results['chunk']:.2f}x")

# temporal plan reuse: selection every k steps, resident chunks in between
print(f"\n{'refresh k':>9s} {'decode io (ms/tok)':>20s}")
for k in (1, 2, 4):
    eng = ServeEngine(model, params, max_seq=512, batch_size=2, device="nano",
                      sparsity=args.sparsity, method="chunk", seed=1,
                      plan_refresh_interval=k)
    last = eng.prefill(prompt)
    tok0 = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    eng.decode(tok0, args.decode_tokens)
    de = [s.io_sim_s for s in eng.stats if s.kind == "decode"]
    print(f"{k:9d} {np.mean(de)*1e3:20.3f}")

print("\n(reduced-model rows are tiny → fragmentation is extreme; the paper's "
      "matched-accuracy full-scale protocol gives 2.19x avg on Nano — see "
      "benchmarks/fig6_tradeoff.py)")
